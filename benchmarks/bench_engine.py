"""Phase-engine benchmark on reduced convex workloads.

Runtimes, same periodic(K) schedule on identical sample draws:

  host          — PhaseEngine.run_host: one jit dispatch per step,
                  averaging decided on host (the seed runtime).
  tree          — PR 1 engine: compiled phase scans, params-pytree carry.
  flat_staged   — flat (M, P) plane + fused averaging, host-staged from
                  an in-memory list (sync).
  flat_prefetch — same list source with prefetch=True: run() now detects
                  the materialized source and skips the prefetch thread,
                  so this column ≈ flat_staged (the PR 2 regression —
                  speedup_prefetch_vs_stack < 1 on every row — is gone).
  stream_sync / stream_prefetch — a TRUE stream source (host indexing +
                  device transfer per step): the double-buffered
                  Prefetcher only ever engages here.
  flat_indexed  — PR 2 engine: flat plane + on-device index blocks, but
                  per-step spec.unpack/spec.pack round-trips around the
                  tree-mapped optimizer (fused_opt=False).
  flat_fusedopt — PR 3 flat-NATIVE engine: optimizer state as (M, P)
                  planes in the scan carry, fused opt_step update —
                  zero per-step pack/unpack.
  flat_sharded  — flat_fusedopt under shard_map over the available
                  devices (psum averaging collective); needs >= 2
                  devices (CI runs it under
                  XLA_FLAGS=--xla_force_host_platform_device_count=8).

Two Momentum workloads: ``ls`` (single-leaf least squares — PR 1/2
continuity; pytree overhead is negligible at one leaf) and ``deep`` (a
36-leaf narrow tanh MLP — the regime the fused optimizer planes target;
the acceptance column ``speedup_fusedopt_vs_flat`` is flat_indexed /
flat_fusedopt). Deep rows sweep scan_unroll: rolled scans let XLA elide
much of the tree path's per-step pack/unpack, unrolled scans (the
CPU-recommended setting for compute-heavy bodies) expose it — the
flat-native carry is robust to both.

Also times the WorkerSharder batched replacement draw and, with >= 2
devices, records whether the gather-collective sharded run is
bit-identical to single-device. An ``adaptive`` row compares the
dispersion-driven schedules (adaptive_threshold with the trip level
self-tuned to 0.7x the periodic run's mean event dispersion;
adaptive_budget with half the periodic communication budget) against
the periodic-8 baseline on
identical draws: final consensus loss vs averaging-event count — the
paper's question, answered by following the measured variance envelope
instead of a fixed clock. A ``topology`` sweep (``repro.topology``)
asks the same question along the mixing-matrix axis: each sparse
topology (ring / torus / hypercube / gossip pairs) runs at the event
period matching periodic-8 full averaging's per-worker communication
budget, recording final loss + dispersion envelope vs spectral gap vs
comm volume — and the ``full``-topology run is checked bit-identical
to the plain mean path (``full_topology_bitexact``, gated like the
sharded-gather check; the ``--tiny`` smoke keeps full+ring+gossip).
A ``compressed`` row (``repro.core.compress``) runs the wire-precision
axis at matched BYTE budgets: int8 + error feedback at the event period
whose realized bytes-on-the-wire fit within 25% of full-f32 periodic-8's
(per ``repro.topology.comm_bytes``), recording final losses and bytes —
plus a ``bf16`` arm at the same period as the baseline (half the
bytes for free). The ``f32`` wire format must lower to the
uncompressed path BIT-exactly (params + full history) — recorded as
``compressed_matches_f32`` and gated like ``full_topology_bitexact``.
A ``faults`` row (``repro.faults``) runs the robustness axis: a
scripted crash + warm-started rejoin with stochastic stragglers must
recover the no-fault final loss within 5% (``dropout_recovers``,
gated in CI), and an IID-vs-dirichlet(0.05) shard comparison records
the non-IID dispersion gap against the variance model's predicted
averaging benefit (``noniid_benefit_agrees``).
An ``elastic`` row (``repro.elastic``) runs the membership axis: a
fixed-M periodic-8 baseline vs the same recipe shrinking to 3M/4 a
quarter of the way in and growing back (4-step rejoin curriculum) at
three quarters — the resized run must recover the fixed-M final loss
within 5% (``elastic_recovers``, gated in CI), and the K-weighted
drift budget (``predict_post_resize_dispersion``, arXiv 1807.06629)
calibrated on dirichlet(0.05) shards must predict the measured
post-resize dispersion within 2x (``envelope_calibrated``).
Topology-sweep rows carry a ``bytes_per_worker`` column pricing their
realized events at every wire format, so matched-budget comparisons
read in bytes, not messages.

Emits JSON via benchmarks/common.py
(results/bench_engine.json). ``--tiny`` runs CI-smoke shapes (no host
baseline; pass ``--save`` to still write JSON for the CI artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, emit, save
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import convex_dataset
from repro.data.pipeline import DeviceDataset, WorkerSharder
from repro.launch.mesh import make_worker_mesh
from repro.optim import SGD, Momentum
from repro.telemetry import JsonlSink, run_meta_record
from repro.telemetry.timing import time_run, timed

DIM, SAMPLES, STEPS = 64, 1024, 512
PHASE_LENS = (1, 4, 8, 64, 512)
DEEP_PHASE_LENS = (1, 8, 64)
WORKER_COUNTS = (4, 16)
AVG_HEAVY_K = 8  # minibatch / periodic K<=8: the averaging-heavy regime
DEEP_LAYERS, DEEP_WIDTH = 16, 32


def ls_mean_loss(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def deep_params(dim):
    ks = jax.random.split(jax.random.PRNGKey(0), DEEP_LAYERS + 1)
    h = DEEP_WIDTH
    p = {"in": {"w": jax.random.normal(ks[0], (dim, h)) * 0.3,
                "b": jnp.zeros(h)}}
    for i in range(DEEP_LAYERS):
        p[f"h{i:02d}"] = {"w": jax.random.normal(ks[i + 1], (h, h)) * 0.3,
                          "b": jnp.zeros(h)}
    p["out"] = {"w": jnp.zeros((h, 1)), "b": jnp.zeros(1)}
    return p


def deep_loss(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["in"]["w"] + params["in"]["b"])
    for i in range(DEEP_LAYERS):
        h = jnp.tanh(h @ params[f"h{i:02d}"]["w"] + params[f"h{i:02d}"]["b"])
    out = (h @ params["out"]["w"] + params["out"]["b"])[:, 0]
    return 0.5 * jnp.mean(jnp.square(out - batch["y"])), {}


def schedule(phase_len: int) -> AveragingSchedule:
    return (AveragingSchedule("minibatch") if phase_len == 1
            else AveragingSchedule("periodic", phase_len))


def make_engine(loss_fn, phase_len: int, *, flat: bool = True,
                fused: bool = True, unroll: int = 1, mesh=None):
    return PhaseEngine(loss_fn, Momentum(lr=0.01, mu=0.9),
                       schedule(phase_len), flat=flat, fused_opt=fused,
                       scan_unroll=unroll, mesh=mesh)


def worker_mesh(workers: int):
    """The production worker mesh when enough devices are visible to
    actually shard, else None (sharded columns skipped)."""
    mesh = make_worker_mesh(workers)
    return mesh if mesh.shape["data"] >= 2 else None


def bench_sharder(workers: int, steps: int, batch: int = 8,
                  reps: int = 5) -> dict:
    """Replacement-mode index generation: batched single draw vs the
    PR 1 per-worker python loop."""
    def loop_draw():  # the old implementation, for comparison
        rngs = [np.random.default_rng(10_007 + i) for i in range(workers)]
        out = np.empty((steps, workers, batch), np.int64)
        for t in range(steps):
            for i in range(workers):
                out[t, i] = rngs[i].integers(0, SAMPLES, batch)
        return out

    def block_draw():
        sh = WorkerSharder(SAMPLES, workers, seed=1, mode="replacement")
        return sh.next_index_block(steps, batch)

    out = {}
    for name, fn in (("loop", loop_draw), ("block", block_draw)):
        fn()
        best = min(timed(fn) for _ in range(reps))
        out[f"sharder_{name}_us"] = best * 1e6
    out["sharder_speedup"] = out["sharder_loop_us"] / out["sharder_block_us"]
    return out


def bench_adaptive(arrays, idx, workers, steps) -> dict:
    """Adaptive dispersion-driven schedules vs the periodic-8 baseline
    on identical sample draws: how much averaging does the measured
    dispersion envelope actually need? Returns one row with final
    consensus losses (full-dataset objective) and averaging-event
    counts. The threshold is self-tuned to 0.7x the periodic run's mean
    event dispersion — just under the level a periodic phase typically
    builds, so averaging triggers as the envelope approaches it — and
    the budget is half the periodic run's events (the tuning recorded
    in the row as ``disp_threshold`` / ``comm_budget``)."""
    Xn, yn = np.asarray(arrays["x"]), np.asarray(arrays["y"])

    def full_loss(f):
        r = Xn @ np.asarray(f["w"]) - yn
        return 0.5 * float(np.mean(r * r))

    def run(sch):
        eng = PhaseEngine(ls_mean_loss, Momentum(lr=0.01, mu=0.9), sch)
        f, h = eng.run({"w": jnp.zeros(Xn.shape[1])},
                       DeviceDataset(arrays, workers, indices=idx),
                       num_workers=workers, seed=3, record_every=1)
        return full_loss(f), h

    loss_p, h_p = run(AveragingSchedule("periodic", 8))
    thr = 0.7 * float(np.mean([v for _, v in h_p["dispersion"]]))
    loss_t, h_t = run(AveragingSchedule(
        "adaptive_threshold", disp_threshold=thr, disp_ema_beta=0.5))
    budget = max(1, h_p["averages"] // 2)
    loss_b, h_b = run(AveragingSchedule(
        "adaptive_budget", comm_budget=budget, budget_horizon=steps))
    row = {
        "workload": "adaptive", "workers": workers, "steps": steps,
        "periodic_final_loss": loss_p,
        "periodic_events": h_p["averages"],
        "disp_threshold": thr,
        "adaptive_threshold_final_loss": loss_t,
        "adaptive_threshold_events": h_t["averages"],
        "comm_budget": budget,
        "adaptive_budget_final_loss": loss_b,
        "adaptive_budget_events": h_b["averages"],
        # the acceptance claim: periodic-K's final loss (3% slack — the
        # convex objective's step-to-step noise band) with fewer events
        "adaptive_reaches_periodic": bool(
            loss_t <= loss_p * 1.03
            and h_t["averages"] < h_p["averages"]),
    }
    emit("engine_adaptive_vs_periodic", row["adaptive_threshold_events"],
         f"periodic8_loss={loss_p:.5f}@{h_p['averages']}ev;"
         f"thresh_loss={loss_t:.5f}@{h_t['averages']}ev;"
         f"budget_loss={loss_b:.5f}@{h_b['averages']}ev;"
         f"reaches_periodic={row['adaptive_reaches_periodic']}")
    return row


def bench_topology(arrays, idx, workers, steps, tiny: bool = False) -> dict:
    """Mixing-topology sweep at matched communication budgets — the
    paper's question along the new ``repro.topology`` axis: at equal
    communication, is FREQUENT SPARSE mixing better than INFREQUENT
    FULL averaging?

    Baseline: periodic-8 full averaging, i.e. (M-1)/8 row-exchanges
    per worker per step (one full-mean event costs M-1 messages per
    worker, a ring event 2, a gossip pairing 1). Every sparse topology
    runs at the event period that matches the baseline's per-step
    budget as closely as its degree allows, on identical sample draws.
    Rows record final consensus loss, the dispersion envelope (mean
    over the last quarter of steps — the Eq. 4 diagnostic the spectral
    gap governs), the spectral gap, and the realized comm volume.

    Also verifies the subsystem's bit-identity anchor: an engine with
    ``Topology.full`` must reproduce the plain mean path EXACTLY
    (params + full history) — recorded as ``full_topology_bitexact``
    and gated in CI like the sharded-gather check."""
    from repro.topology import Topology, comm_bytes
    Xn, yn = np.asarray(arrays["x"]), np.asarray(arrays["y"])

    def full_loss(f):
        r = Xn @ np.asarray(f["w"]) - yn
        return 0.5 * float(np.mean(r * r))

    def run(sch, topo):
        eng = PhaseEngine(ls_mean_loss, Momentum(lr=0.01, mu=0.9), sch,
                          topology=topo)
        f, h = eng.run({"w": jnp.zeros(Xn.shape[1])},
                       DeviceDataset(arrays, workers, indices=idx),
                       num_workers=workers, seed=5, record_every=1)
        return f, full_loss(f), h

    base_period = 8
    base_sch = AveragingSchedule("periodic", base_period)
    f_plain, loss_plain, h_plain = run(base_sch, None)
    f_full, loss_full, h_full = run(base_sch, Topology.full(workers))
    bitexact = bool(
        (np.asarray(f_plain["w"]) == np.asarray(f_full["w"])).all()
        and h_plain == h_full)

    budget = (workers - 1) / base_period  # msgs/worker/step, baseline
    rows = []
    kinds = ["full", "ring", "gossip_pairs"]
    if not tiny:
        kinds += ["torus", "hypercube", "disconnected"]

    dim = Xn.shape[1]

    def row_of(topo, period, loss, hist):
        tail = [v for t, v in hist["disp_trace"] if t > steps * 3 // 4]
        return {
            "workload": "topology", "topology": topo.kind,
            "workers": workers, "steps": steps,
            "spectral_gap": topo.spectral_gap,
            "comm_degree": topo.comm_degree, "period": period,
            "events": hist["averages"],
            "comm_per_worker": hist["averages"] * topo.comm_degree,
            # the realized events priced at each wire format
            # (repro.topology.comm_bytes): matched-budget comparisons
            # in bytes, the currency the adaptive_bytes schedule spends
            "bytes_per_worker": {
                w: comm_bytes(topo, hist["averages"], dim, w)
                for w in ("f32", "bf16", "int8")},
            "final_loss": loss,
            "disp_tail_mean": float(np.mean(tail)) if tail else 0.0,
        }

    for kind in kinds:
        try:
            topo = Topology.build(kind, workers)
        except ValueError as e:  # e.g. prime M for torus in a sweep
            rows.append({"workload": "topology", "topology": kind,
                         "workers": workers, "skipped": str(e)})
            continue
        if kind == "full":
            period, (loss, h) = base_period, (loss_full, h_full)
        else:
            period = (max(1, round(topo.comm_degree / budget))
                      if topo.comm_degree > 0 else base_period)
            _, loss, h = run(AveragingSchedule("periodic", period), topo)
        rows.append(row_of(topo, period, loss, h))

    by_kind = {r["topology"]: r for r in rows if "skipped" not in r}
    ring, full = by_kind.get("ring"), by_kind["full"]
    headline = ""
    if ring:
        headline = (f"ring@K{ring['period']}_loss={ring['final_loss']:.5f}"
                    f"({ring['comm_per_worker']:.0f}msg);"
                    f"full@K{full['period']}_loss={full['final_loss']:.5f}"
                    f"({full['comm_per_worker']:.0f}msg)")
    emit("engine_topology_sweep", 0.0 if bitexact else 1.0,
         f"full_topology_bitexact={bitexact};{headline}")
    if not bitexact:
        # same CI contract as the sharded-gather check: a regression in
        # the full-topology bit-identity must fail the PR, not just
        # flip a field in the JSON artifact
        raise SystemExit(
            "Topology.full engine run is NOT bit-identical to the mean "
            "path")
    return {"full_topology_bitexact": bitexact,
            "baseline_period": base_period,
            "budget_msgs_per_worker_step": budget, "rows": rows}


def bench_compressed(arrays, idx, workers, steps) -> dict:
    """Wire-precision sweep at matched BYTE budgets — the paper's
    communication question in the currency production actually pays:
    can int8 rows + error feedback reach full-f32 periodic-8's final
    loss at <= 25% of the bytes-on-the-wire?

    Baseline: uncompressed periodic-8 full averaging. The int8 arm
    runs at the smallest event period whose realized wire bytes
    (``repro.topology.comm_bytes`` — events x (M-1) messages, each one
    encoded row of ``wire_row_bytes``) fit the 25% budget; int8 rows
    cost ~26.6% of f32 rows at these widths, so a slightly longer
    period buys the rest. A ``bf16`` arm rides the baseline period
    (50% of the bytes with no shared randomness). All arms run on
    identical sample draws.

    Also verifies the axis's bit-identity anchor: an engine with
    ``Compression("f32")`` must reproduce the uncompressed path
    EXACTLY (params + full history) — recorded as
    ``compressed_matches_f32`` and gated in CI like
    ``full_topology_bitexact``."""
    from repro.core import Compression
    from repro.topology import Topology, comm_bytes
    Xn, yn = np.asarray(arrays["x"]), np.asarray(arrays["y"])
    dim = Xn.shape[1]
    topo = Topology.full(workers)

    def full_loss(f):
        r = Xn @ np.asarray(f["w"]) - yn
        return 0.5 * float(np.mean(r * r))

    def run(period, comp):
        eng = PhaseEngine(ls_mean_loss, Momentum(lr=0.01, mu=0.9),
                          AveragingSchedule("periodic", period),
                          compression=comp)
        f, h = eng.run({"w": jnp.zeros(dim)},
                       DeviceDataset(arrays, workers, indices=idx),
                       num_workers=workers, seed=7, record_every=1)
        return f, full_loss(f), h

    base_period = 8
    f_plain, loss_f32, h_plain = run(base_period, None)
    f_id, loss_id, h_id = run(base_period, Compression("f32"))
    matches = bool(
        (np.asarray(f_plain["w"]) == np.asarray(f_id["w"])).all()
        and h_plain == h_id)

    bytes_f32 = comm_bytes(topo, h_plain["averages"], dim, "f32")
    budget = bytes_f32 // 4  # the 25%-of-the-bytes acceptance budget

    # smallest int8 period whose expected events fit the byte budget:
    # more frequent averaging is strictly better, so spend it all
    period_i8 = base_period
    while comm_bytes(topo, steps // period_i8, dim, "int8") > budget:
        period_i8 += 1
    _, loss_i8, h_i8 = run(period_i8, Compression("int8"))
    bytes_i8 = comm_bytes(topo, h_i8["averages"], dim, "int8")

    _, loss_bf16, h_bf16 = run(base_period, Compression("bf16"))
    bytes_bf16 = comm_bytes(topo, h_bf16["averages"], dim, "bf16")

    row = {
        "workload": "compressed", "workers": workers, "steps": steps,
        "f32_period": base_period, "f32_events": h_plain["averages"],
        "f32_bytes_per_worker": bytes_f32, "f32_final_loss": loss_f32,
        "bf16_period": base_period, "bf16_events": h_bf16["averages"],
        "bf16_bytes_per_worker": bytes_bf16,
        "bf16_final_loss": loss_bf16,
        "int8_period": period_i8, "int8_events": h_i8["averages"],
        "int8_bytes_per_worker": bytes_i8, "int8_final_loss": loss_i8,
        "int8_bytes_fraction": bytes_i8 / bytes_f32,
        # the acceptance claim: full-f32 periodic-8's final loss (3%
        # slack — the convex objective's step-to-step noise band) at
        # <= 25% of the bytes on the wire
        "int8_reaches_f32": bool(loss_i8 <= loss_f32 * 1.03
                                 and bytes_i8 * 4 <= bytes_f32),
        "compressed_matches_f32": matches,
    }
    emit("engine_compressed_vs_f32", 0.0 if matches else 1.0,
         f"compressed_matches_f32={matches};"
         f"f32_loss={loss_f32:.5f}@{bytes_f32}B;"
         f"int8_loss={loss_i8:.5f}@{bytes_i8}B"
         f"({row['int8_bytes_fraction']:.0%});"
         f"int8_reaches_f32={row['int8_reaches_f32']}")
    if not matches:
        # same CI contract as full_topology_bitexact: a regression in
        # the f32-wire bit-identity must fail the PR, not just flip a
        # field in the JSON artifact
        raise SystemExit(
            "Compression('f32') engine run is NOT bit-identical to the "
            "uncompressed path")
    return row


def bench_faults(arrays, idx, workers, steps) -> dict:
    """Robustness sweep along the fault + heterogeneity axes.

    Crash/rejoin recovery: a no-fault periodic-8 Momentum baseline vs
    the same engine under a scripted fault plan — one worker crashes a
    quarter of the way in, rejoins (warm-started from the alive
    average) at three quarters, with 5% stochastic stragglers
    throughout — on identical sample draws. The acceptance claim is
    ``dropout_recovers``: the faulted run's final consensus loss lands
    within 5% of the no-fault run's (the rejoined worker re-converges
    instead of dragging the consensus), gated in CI like
    ``compressed_matches_f32``.

    Heterogeneity: an IID (replacement) vs non-IID (per-class
    dirichlet(0.05) label skew over target-quantile pseudo-classes)
    sampled run at the same schedule. Non-IID shards hold worker
    iterates apart between events, so the recorded mean event
    dispersion gap ``noniid_disp_gap`` must be positive — and the
    variance model must agree (``noniid_benefit_agrees``). Label skew
    SHRINKS within-pool gradient variance (a near-single-class pool is
    more homogeneous than the full dataset); what widens the envelope
    is the coherent drift of pool-mean gradients, which accumulates
    linearly in iterate space over the K local steps between events
    (vs sqrt(K) for noise) and so enters the per-event variance budget
    with weight K. ``predict_averaging_benefit`` on that drift-aware
    budget must predict a larger averaging benefit for the skewed
    shards than the IID budget predicts."""
    from repro.core import FaultPlan, predict_averaging_benefit
    Xn, yn = np.asarray(arrays["x"]), np.asarray(arrays["y"])
    dim = Xn.shape[1]

    def full_loss(f):
        r = Xn @ np.asarray(f["w"]) - yn
        return 0.5 * float(np.mean(r * r))

    def run(data, faults=None, run_steps=None):
        eng = PhaseEngine(ls_mean_loss, Momentum(lr=0.01, mu=0.9),
                          AveragingSchedule("periodic", 8), faults=faults)
        f, h = eng.run({"w": jnp.zeros(dim)}, data, num_workers=workers,
                       seed=7, record_every=1, steps=run_steps)
        return full_loss(f), h

    loss_clean, h_clean = run(DeviceDataset(arrays, workers, indices=idx))
    t_crash, t_rejoin = max(1, steps // 4), max(2, 3 * steps // 4)
    plan = FaultPlan.parse(
        f"crash:m=1@t={t_crash},rejoin:m=1@t={t_rejoin}", workers,
        straggle_prob=0.05)
    loss_fault, h_fault = run(DeviceDataset(arrays, workers, indices=idx),
                              faults=plan)
    recovers = bool(loss_fault <= loss_clean * 1.05)

    # pseudo-classes for label skew: quartiles of the regression target
    labels = np.digitize(yn, np.quantile(yn, [0.25, 0.5, 0.75]))

    def sampled(mode, alpha):
        return run(DeviceDataset(arrays, workers, batch_size=8, seed=11,
                                 mode=mode, labels=labels, alpha=alpha),
                   run_steps=steps)

    loss_iid, h_iid = sampled("replacement", 0.5)
    loss_ni, h_ni = sampled("dirichlet", 0.05)
    disp_iid = float(np.mean([v for _, v in h_iid["dispersion"]]))
    disp_ni = float(np.mean([v for _, v in h_ni["dispersion"]]))

    # per-pool gradient statistics at w0 = 0 (per-sample grad =
    # -x_i y_i): noise = variance around the pool's own mean, drift =
    # the pool mean's offset from the global mean. Noise accumulates
    # as sqrt(K) over the K steps between events, drift coherently as
    # K — so the per-event variance budget weights drift by K
    sh = WorkerSharder(len(yn), workers, seed=11, mode="dirichlet",
                       labels=labels, alpha=0.05)
    grads = -Xn * yn[:, None]
    gbar = grads.mean(0)

    def pool_noise(pool):
        g = grads[pool]
        return float(np.mean(np.sum((g - g.mean(0)) ** 2, axis=1)))

    def pool_drift(pool):
        return float(np.sum((grads[pool].mean(0) - gbar) ** 2))

    period = 8
    s2_ni = [pool_noise(p) + period * pool_drift(p) for p in sh._pools]
    s2_iid = [pool_noise(np.arange(len(yn)))] * workers
    drift = float(np.mean([pool_drift(p) for p in sh._pools]))
    pred_ni = predict_averaging_benefit(s2_ni)
    pred_iid = predict_averaging_benefit(s2_iid)
    alive = np.ones(workers)
    alive[1] = 0.0
    pred_degraded = predict_averaging_benefit(s2_iid, alive=alive)

    row = {
        "workload": "faults", "workers": workers, "steps": steps,
        "fault_plan": f"crash:m=1@t={t_crash},rejoin:m=1@t={t_rejoin}",
        "straggle_prob": 0.05,
        "clean_final_loss": loss_clean, "clean_events": h_clean["averages"],
        "faulted_final_loss": loss_fault,
        "faulted_events": h_fault["averages"],
        "dropout_recovers": recovers,
        "iid_final_loss": loss_iid, "iid_mean_event_disp": disp_iid,
        "noniid_final_loss": loss_ni, "noniid_mean_event_disp": disp_ni,
        "noniid_disp_gap": disp_ni - disp_iid,
        "noniid_grad_drift": drift,
        "noniid_sigma2_bar": pred_ni["sigma2_bar"],
        "iid_sigma2_bar": pred_iid["sigma2_bar"],
        "noniid_predicted_benefit": pred_ni["benefit"],
        "iid_predicted_benefit": pred_iid["benefit"],
        "noniid_benefit_agrees": bool(
            disp_ni > disp_iid
            and pred_ni["benefit"] > pred_iid["benefit"]),
        "degraded_variance_reduction": pred_degraded["variance_reduction"],
    }
    emit("engine_faults_recovery", 0.0 if recovers else 1.0,
         f"clean_loss={loss_clean:.5f};fault_loss={loss_fault:.5f};"
         f"dropout_recovers={recovers};"
         f"noniid_disp_gap={row['noniid_disp_gap']:.4f};"
         f"benefit_agrees={row['noniid_benefit_agrees']}")
    if not recovers:
        # same CI contract as compressed_matches_f32: losing the
        # crash+rejoin recovery property must fail the PR, not just
        # flip a field in the JSON artifact
        raise SystemExit(
            f"faulted run does NOT recover: final loss {loss_fault:.6f} "
            f"vs no-fault {loss_clean:.6f} (budget 5%)")
    return row


def bench_elastic(arrays, idx, workers, steps, labels) -> dict:
    """Elastic-membership sweep (``repro.elastic``).

    Recovery: a fixed-M periodic-8 Momentum baseline vs the same recipe
    losing a quarter of its workers a quarter of the way in
    (shrink M -> 3M/4 at steps/4) and getting them back at three
    quarters (grow back, 4-step rejoin curriculum), on identical sample
    draws — at the default shapes that is the ISSUE's 16 -> 12 at
    t=128, back to 16 at t=384. The acceptance claim is
    ``elastic_recovers``: the resized run's final consensus loss lands
    within 5% of the fixed-M run's (the noise band the other recovery
    gates use), gated in CI like ``dropout_recovers``.

    Calibration: an SGD run on dirichlet(0.05) label-skewed shards
    exercises ``predict_post_resize_dispersion`` — the K-weighted
    drift budget of Parallel Restarted SGD (arXiv 1807.06629) — as a
    MAGNITUDE predictor, not just a direction: per-pool gradient noise
    (sigma^2 / batch), pool-mean drift and the pool-curvature
    contraction rate along it are measured at the consensus reached by
    the averaging event at the grow-back step, and the predicted
    K=8-step dispersion must land within 2x of the dispersion the
    engine actually records one period later
    (``envelope_calibrated``, gated the same way)."""
    from repro.core import predict_post_resize_dispersion
    from repro.elastic import ElasticPlan, run_elastic
    Xn, yn = np.asarray(arrays["x"]), np.asarray(arrays["y"])
    dim = Xn.shape[1]

    def full_loss(f):
        r = Xn @ np.asarray(f["w"]) - yn
        return 0.5 * float(np.mean(r * r))

    t1, t2 = max(2, steps // 4), 3 * steps // 4
    m1 = max(1, 3 * workers // 4)
    plan = ElasticPlan.parse(workers, shrink_at=[f"{t1}:{m1}"],
                             grow_at=[f"{t2}:{workers}"], curriculum=4)

    def factory(m, t0, k):
        return DeviceDataset(arrays, m,
                             indices=idx[t0 - 1:t0 - 1 + k, :m])

    def run_fixed():
        eng = PhaseEngine(ls_mean_loss, Momentum(lr=0.01, mu=0.9),
                          AveragingSchedule("periodic", 8))
        f, h = eng.run({"w": jnp.zeros(dim)},
                       DeviceDataset(arrays, workers, indices=idx),
                       num_workers=workers, seed=6, record_every=1)
        return full_loss(f), h

    loss_fixed, h_fixed = run_fixed()
    eng = PhaseEngine(ls_mean_loss, Momentum(lr=0.01, mu=0.9),
                      AveragingSchedule("periodic", 8))
    f_el, h_el = run_elastic(eng, {"w": jnp.zeros(dim)}, factory, plan,
                             steps=steps, seed=6, record_every=1)
    loss_el = full_loss(f_el)
    recovers = bool(loss_el <= loss_fixed * 1.05)

    # ---- calibration: predicted vs measured post-resize dispersion ----
    # dirichlet(0.05) shards, SGD (the K-window weights c_j = lr exactly;
    # momentum's velocity carry-over from BEFORE the window would break
    # the from-consensus assumption), curriculum 0 so the grown rows
    # enter the mix — and the model's n — immediately
    lr, period = 0.01, 8
    sh = WorkerSharder(len(yn), workers, seed=13, mode="dirichlet",
                       labels=labels, alpha=0.05)
    cal_steps = t2 + period
    block = sh.next_index_block(cal_steps, 8)

    def cal_factory(m, t0, k):
        return DeviceDataset(arrays, m,
                             indices=block[t0 - 1:t0 - 1 + k, :m])

    cal_plan = ElasticPlan.parse(workers, shrink_at=[f"{t1}:{m1}"],
                                 grow_at=[f"{t2}:{workers}"])
    cal_eng = PhaseEngine(ls_mean_loss, SGD(lr=lr),
                          AveragingSchedule("periodic", period))
    # stop at the averaging event DURING step t2 (t2 % 8 == 0): every
    # row — survivors and grown alike — leaves it at the consensus w_c,
    # so the next period is exactly the model's from-consensus K-window
    w_c, _, st = run_elastic(cal_eng, {"w": jnp.zeros(dim)}, cal_factory,
                             cal_plan, steps=t2, seed=6,
                             return_state=True)
    _, h_cal = run_elastic(cal_eng, {"w": jnp.zeros(dim)}, cal_factory,
                           cal_plan, steps=cal_steps, seed=6,
                           record_every=1, state=st)
    measured = float(dict(h_cal["dispersion"])[cal_steps])

    # per-pool gradient statistics AT w_c: per-sample grad of the
    # 0.5*mean(r^2) objective is x_i r_i; a B-sample batch mean has
    # sigma^2_pool / B of it. Pool-mean drifts are centered on the
    # ACROSS-POOL mean (dispersion is measured against the worker
    # mean, which tracks it, not the full-data gradient), and the
    # contraction rate each drift decays at is the pool Hessian's
    # Rayleigh quotient along it, weighted by drift mass
    wc = np.asarray(w_c["w"])
    g = Xn * (Xn @ wc - yn)[:, None]
    means = np.stack([g[p].mean(0) for p in sh._pools])
    s2 = [float(np.mean(np.sum((g[p] - g[p].mean(0)) ** 2, axis=1))) / 8
          for p in sh._pools]
    drift2 = float(np.mean(np.sum((means - means.mean(0)) ** 2, axis=1)))
    lams = np.array([float(d @ (Xn[p].T @ Xn[p] / len(p)) @ d / (d @ d))
                     for p, d in zip(sh._pools, means)])
    w2 = np.sum(means ** 2, axis=1)
    curvature = float(np.sum(w2 * lams) / np.sum(w2))
    pred = predict_post_resize_dispersion(s2, lr=lr, steps=period,
                                          drift2=drift2,
                                          curvature=curvature)
    predicted = pred["predicted_dispersion"]
    ratio = measured / predicted if predicted > 0 else float("inf")
    calibrated = bool(0.5 <= ratio <= 2.0)

    row = {
        "workload": "elastic", "workers": workers, "steps": steps,
        "plan": f"shrink@{t1}:{m1},grow@{t2}:{workers}",
        "curriculum": 4,
        "fixed_final_loss": loss_fixed,
        "fixed_events": h_fixed["averages"],
        "elastic_final_loss": loss_el,
        "elastic_events": h_el["averages"],
        "resizes": h_el["resizes"],
        "elastic_recovers": recovers,
        "calib_measured_disp": measured,
        "calib_predicted_disp": predicted,
        "calib_drift2": drift2,
        "calib_curvature": curvature,
        "calib_noise_disp": pred["noise_dispersion"],
        "calib_drift_disp": pred["drift_dispersion"],
        "calib_ratio": ratio,
        "envelope_calibrated": calibrated,
    }
    emit("engine_elastic_recovery", 0.0 if recovers else 1.0,
         f"fixed_loss={loss_fixed:.5f};elastic_loss={loss_el:.5f};"
         f"elastic_recovers={recovers};"
         f"disp_pred={predicted:.5g};disp_meas={measured:.5g}"
         f"({ratio:.2f}x);envelope_calibrated={calibrated}")
    if not recovers:
        # same CI contract as dropout_recovers: losing the resize
        # recovery property must fail the PR, not just flip a field in
        # the JSON artifact
        raise SystemExit(
            f"elastic run does NOT recover: final loss {loss_el:.6f} "
            f"vs fixed-M {loss_fixed:.6f} (budget 5%)")
    if not calibrated:
        raise SystemExit(
            f"post-resize dispersion prediction is OFF: predicted "
            f"{predicted:.6g} vs measured {measured:.6g} "
            f"({ratio:.2f}x, budget [0.5, 2.0])")
    return row


def check_sharded_bitexact(loss_fn, params, arrays, idx, workers,
                           mesh) -> bool:
    """gather-collective sharded run == single-device run, bitwise —
    final params AND the full history (losses, dispersions, decisions).
    Holds for SGD/Momentum (mul-add update math lowers identically in
    both compilation contexts on every backend tested); AdamW's
    div/sqrt and deep matmul losses agree to f32 roundoff instead, so
    the recorded guarantee is scoped to the paper's Momentum recipe on
    the convex workload (tests/test_sharded.py covers all 5
    schedules)."""
    kw = dict(num_workers=workers, seed=3, record_every=1)
    sch = AveragingSchedule("periodic", 8)
    single = PhaseEngine(loss_fn, Momentum(lr=0.01, mu=0.9), sch)
    f0, h0 = single.run(params, DeviceDataset(arrays, workers, indices=idx),
                        **kw)
    sharded = PhaseEngine(loss_fn, Momentum(lr=0.01, mu=0.9), sch,
                          mesh=mesh, collective="gather")
    f1, h1 = sharded.run(params, DeviceDataset(arrays, workers,
                                               indices=idx), **kw)
    same = all(bool((np.asarray(a) == np.asarray(b)).all())
               for a, b in zip(jax.tree.leaves(f0), jax.tree.leaves(f1)))
    return same and h0 == h1


def run(tiny: bool = False, workers_override: int | None = None,
        save_json: bool | None = None):
    steps = 64 if tiny else STEPS
    phase_lens = (1, 8) if tiny else PHASE_LENS
    deep_phase_lens = (8,) if tiny else DEEP_PHASE_LENS
    worker_counts = (4,) if tiny else WORKER_COUNTS
    if workers_override:
        worker_counts = (workers_override,)
    dim, samples = (16, 256) if tiny else (DIM, SAMPLES)
    reps = 1 if tiny else 3
    if save_json is None:
        save_json = not tiny

    X, y, _ = convex_dataset("ls", samples, dim, sparsity=0.2, noise=0.1,
                             seed=0)
    Xn, yn = np.asarray(X), np.asarray(y)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    w0 = {"w": jnp.zeros(dim)}

    results = []
    for workers in worker_counts:
        mesh = worker_mesh(workers)
        rng = np.random.default_rng(0)
        idx = rng.integers(0, samples, size=(steps, workers, 8))
        batches = [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(steps)]

        def stream():
            for t in range(steps):
                yield {"x": jnp.asarray(Xn[idx[t]]),
                       "y": jnp.asarray(yn[idx[t]])}

        for k in phase_lens:
            # small-K schedules still scan big blocks: averaging decisions
            # are per-step and on-device, so one compiled block may span
            # many averaging periods
            block = max(k, 64)
            tree_eng = make_engine(ls_mean_loss, k, flat=False)
            pr2_eng = make_engine(ls_mean_loss, k, fused=False)
            fused_eng = make_engine(ls_mean_loss, k)

            def staged(eng, data_fn, prefetch):
                # data_fn: factory — generators are consumed per run
                return lambda: eng.run(w0, data_fn(), num_workers=workers,
                                       seed=0, phase_len=block,
                                       prefetch=prefetch)

            def indexed(eng):
                return lambda: eng.run(
                    w0, DeviceDataset({"x": Xj, "y": yj}, workers,
                                      indices=idx),
                    num_workers=workers, seed=0, phase_len=block)

            row = {"workload": "ls", "workers": workers, "phase_len": k,
                   "steps": steps, "scan_unroll": 1}
            if not tiny:
                row["host_ms_per_step"] = time_run(
                    lambda: tree_eng.run_host(w0, batches,
                                              num_workers=workers, seed=0),
                    steps, reps=reps)
            row["tree_ms_per_step"] = time_run(
                staged(tree_eng, lambda: batches, False), steps, reps=reps)
            row["flat_staged_ms_per_step"] = time_run(
                staged(fused_eng, lambda: batches, False), steps, reps=reps)
            row["flat_prefetch_ms_per_step"] = time_run(
                staged(fused_eng, lambda: batches, True), steps, reps=reps)
            row["stream_sync_ms_per_step"] = time_run(
                staged(fused_eng, stream, False), steps, reps=reps)
            row["stream_prefetch_ms_per_step"] = time_run(
                staged(fused_eng, stream, True), steps, reps=reps)
            row["flat_indexed_ms_per_step"] = time_run(
                indexed(pr2_eng), steps, reps=reps)
            row["flat_fusedopt_ms_per_step"] = time_run(
                indexed(fused_eng), steps, reps=reps)
            if mesh is not None:
                sharded_eng = make_engine(ls_mean_loss, k, mesh=mesh)
                row["flat_sharded_ms_per_step"] = time_run(
                    indexed(sharded_eng), steps, reps=reps)
            row["speedup_flat_vs_tree"] = (row["tree_ms_per_step"] /
                                           row["flat_fusedopt_ms_per_step"])
            row["speedup_fusedopt_vs_flat"] = (
                row["flat_indexed_ms_per_step"] /
                row["flat_fusedopt_ms_per_step"])
            row["speedup_prefetch_vs_stack"] = (
                row["flat_staged_ms_per_step"] /
                row["flat_prefetch_ms_per_step"])
            row["speedup_stream_prefetch"] = (
                row["stream_sync_ms_per_step"] /
                row["stream_prefetch_ms_per_step"])
            if not tiny:
                row["speedup_vs_host"] = (row["host_ms_per_step"] /
                                          row["flat_fusedopt_ms_per_step"])
            results.append(row)
            emit(f"engine_ls_K{k}_M{workers}",
                 row["flat_fusedopt_ms_per_step"] * 1e3,
                 f"tree_ms/step={row['tree_ms_per_step']:.3f};"
                 f"fusedopt_ms/step="
                 f"{row['flat_fusedopt_ms_per_step']:.3f};"
                 f"flat_vs_tree={row['speedup_flat_vs_tree']:.2f}x;"
                 f"fusedopt_vs_flat="
                 f"{row['speedup_fusedopt_vs_flat']:.2f}x;"
                 f"prefetch_vs_stack="
                 f"{row['speedup_prefetch_vs_stack']:.2f}x")

        # deep multi-leaf Momentum workload: the fused-optimizer target
        dp = deep_params(dim)
        for k in deep_phase_lens:
            for unroll in ((4,) if tiny else (1, 4)):
                block = 256 if not tiny else 64
                pr2_eng = make_engine(deep_loss, k, fused=False,
                                      unroll=unroll)
                fused_eng = make_engine(deep_loss, k, unroll=unroll)

                def indexed_deep(eng):
                    return lambda: eng.run(
                        dp, DeviceDataset({"x": Xj, "y": yj}, workers,
                                          indices=idx),
                        num_workers=workers, seed=0, phase_len=block)

                row = {"workload": "deep", "workers": workers,
                       "phase_len": k, "steps": steps,
                       "scan_unroll": unroll,
                       "num_leaves": len(jax.tree.leaves(dp))}
                row["flat_indexed_ms_per_step"] = time_run(
                    indexed_deep(pr2_eng), steps, reps=reps)
                row["flat_fusedopt_ms_per_step"] = time_run(
                    indexed_deep(fused_eng), steps, reps=reps)
                if mesh is not None:
                    row["flat_sharded_ms_per_step"] = time_run(
                        indexed_deep(make_engine(deep_loss, k,
                                                 unroll=unroll, mesh=mesh)),
                        steps, reps=reps)
                row["speedup_fusedopt_vs_flat"] = (
                    row["flat_indexed_ms_per_step"] /
                    row["flat_fusedopt_ms_per_step"])
                results.append(row)
                emit(f"engine_deep_K{k}_M{workers}_u{unroll}",
                     row["flat_fusedopt_ms_per_step"] * 1e3,
                     f"indexed_ms/step="
                     f"{row['flat_indexed_ms_per_step']:.3f};"
                     f"fusedopt_ms/step="
                     f"{row['flat_fusedopt_ms_per_step']:.3f};"
                     f"fusedopt_vs_flat="
                     f"{row['speedup_fusedopt_vs_flat']:.2f}x")

    m_adapt = max(worker_counts)
    rng = np.random.default_rng(2)
    aidx = rng.integers(0, samples, size=(steps, m_adapt, 8))
    adaptive_row = bench_adaptive({"x": Xj, "y": yj}, aidx, m_adapt, steps)
    results.append(adaptive_row)

    rng = np.random.default_rng(3)
    tidx = rng.integers(0, samples, size=(steps, m_adapt, 8))
    topology_sweep = bench_topology({"x": Xj, "y": yj}, tidx, m_adapt,
                                    steps, tiny=tiny)
    results.extend(topology_sweep["rows"])

    rng = np.random.default_rng(4)
    xidx = rng.integers(0, samples, size=(steps, m_adapt, 8))
    compressed_row = bench_compressed({"x": Xj, "y": yj}, xidx, m_adapt,
                                      steps)
    results.append(compressed_row)

    rng = np.random.default_rng(5)
    fidx = rng.integers(0, samples, size=(steps, m_adapt, 8))
    faults_row = bench_faults({"x": Xj, "y": yj}, fidx, m_adapt, steps)
    results.append(faults_row)

    rng = np.random.default_rng(6)
    eidx = rng.integers(0, samples, size=(steps, m_adapt, 8))
    labels = np.digitize(yn, np.quantile(yn, [0.25, 0.5, 0.75]))
    elastic_row = bench_elastic({"x": Xj, "y": yj}, eidx, m_adapt, steps,
                                labels)
    results.append(elastic_row)

    sharder = bench_sharder(max(worker_counts), steps)
    emit("sharder_replacement", sharder["sharder_block_us"],
         f"loop_us={sharder['sharder_loop_us']:.0f};"
         f"block_us={sharder['sharder_block_us']:.0f};"
         f"speedup={sharder['sharder_speedup']:.1f}x")

    sharded_bitexact = None
    mesh = worker_mesh(max(worker_counts))
    if mesh is not None:
        m = max(worker_counts)
        rng = np.random.default_rng(1)
        cidx = rng.integers(0, samples, size=(33, m, 8))
        sharded_bitexact = check_sharded_bitexact(
            ls_mean_loss, {"w": jnp.zeros(dim)}, {"x": Xj, "y": yj},
            cidx, m, mesh)
        emit("engine_sharded_bitexact", 0.0 if sharded_bitexact else 1.0,
             f"gather-collective == single-device: {sharded_bitexact}")
        if not sharded_bitexact:
            # the bench-smoke CI job gates on this: a regression in the
            # gather-collective bit-identity must fail the PR, not just
            # flip a field in the JSON artifact
            raise SystemExit(
                "sharded gather-collective run is NOT bit-identical to "
                "single-device")

    fused = [r["speedup_fusedopt_vs_flat"] for r in results
             if r["workload"] == "deep"]
    heavy = [r["speedup_flat_vs_tree"] for r in results
             if r["workload"] == "ls" and r["phase_len"] <= AVG_HEAVY_K]
    if heavy:
        print(f"min flat-vs-tree speedup at K<={AVG_HEAVY_K}: "
              f"{min(heavy):.2f}x")
    if fused:
        print(f"max fusedopt-vs-PR2-flat speedup (deep workload): "
              f"{max(fused):.2f}x")
    if save_json:
        # a small telemetry-enabled run next to the timing JSON: the CI
        # artifact a reader can render with python -m repro.telemetry.report
        tele_path = os.path.join(RESULTS_DIR, "bench_engine_telemetry.jsonl")
        tele_workers = worker_counts[0]
        rng = np.random.default_rng(7)
        tidx = rng.integers(0, samples, size=(steps, tele_workers, 8))
        tele_eng = dataclasses.replace(
            make_engine(ls_mean_loss, 8), telemetry=True)
        with JsonlSink(tele_path) as sink:
            sink.emit(run_meta_record(config={
                "workload": "ls", "workers": tele_workers,
                "steps": steps, "avg": "periodic", "phase_len": 8,
                "lr": 0.01, "momentum": 0.9, "optimizer": "momentum"}))
            tele_eng.run(w0, DeviceDataset({"x": Xj, "y": yj},
                                           tele_workers, indices=tidx),
                         num_workers=tele_workers, seed=0, phase_len=64,
                         sink=sink)
        print(f"telemetry log -> {tele_path}")
        save("bench_engine", {
            "run_meta": run_meta_record(),
            "workload": {"dim": dim, "samples": samples, "steps": steps,
                         "kind": "ls+deep", "optimizer": "momentum",
                         "deep_layers": DEEP_LAYERS,
                         "deep_width": DEEP_WIDTH},
            "devices": len(jax.devices()),
            "sharded_gather_bitexact": sharded_bitexact,
            "adaptive": adaptive_row,
            "topology": topology_sweep,
            "compressed": compressed_row,
            "faults": faults_row,
            "elastic": elastic_row,
            "rows": results, "sharder": sharder})
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--save", action="store_true",
                    help="write results/bench_engine.json even with --tiny")
    ap.add_argument("--workers", type=int, default=None,
                    help="override the worker-count sweep (CI smoke runs "
                         "--workers 8 under forced host device count to "
                         "exercise the sharded path)")
    args = ap.parse_args()
    run(tiny=args.tiny, workers_override=args.workers,
        save_json=args.save or not args.tiny)

"""Phase-engine benchmark on the reduced convex (least-squares) workload.

Four runtimes, same periodic(K) schedule on identical sample draws:

  host         — PhaseEngine.run_host: one jit dispatch per step,
                 averaging decided on host (the seed runtime).
  tree         — PR 1 engine: compiled phase scans, params-pytree carry,
                 per-phase host staging (tree_stack), no prefetch.
  flat_staged  — flat (M, P) plane carry + fused avg_disp averaging,
                 still host-staged (sync and prefetch variants — the
                 prefetch-vs-stack column).
  flat_indexed — the full device-resident pipeline: flat plane + fused
                 kernel + on-device data plane (DeviceDataset index
                 blocks gathered inside the scan; zero host stacking).

Sweeps K in {1, 4, 8, 64, 512} x workers in {4, 16}; the acceptance
column is ``speedup_flat_vs_tree`` (tree / flat_indexed) on the
averaging-heavy schedules (minibatch / periodic K<=8). Also times the
WorkerSharder setup cost: the batched replacement draw vs the PR 1
per-worker python loop. Emits JSON via benchmarks/common.py
(results/bench_engine.json). ``--tiny`` runs CI-smoke shapes (no host
baseline, no JSON).
"""
from __future__ import annotations

import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import convex_dataset
from repro.data.pipeline import DeviceDataset, WorkerSharder
from repro.optim import SGD

DIM, SAMPLES, STEPS = 64, 1024, 512
PHASE_LENS = (1, 4, 8, 64, 512)
WORKER_COUNTS = (4, 16)
AVG_HEAVY_K = 8  # minibatch / periodic K<=8: the averaging-heavy regime


def loss_fn(params, batch, rng):
    return 0.5 * jnp.square(batch["x"] @ params["w"] - batch["y"]), {}


def make_engine(phase_len: int, *, flat: bool):
    sch = (AveragingSchedule("minibatch") if phase_len == 1
           else AveragingSchedule("periodic", phase_len))
    return PhaseEngine(loss_fn, SGD(lr=0.01), sch, flat=flat)


def time_run(fn, steps, *, reps: int = 3) -> float:
    """ms/step, best of ``reps`` after a compile warmup run."""
    fn()  # warmup: compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / steps * 1e3


def bench_sharder(workers: int, steps: int, batch: int = 8,
                  reps: int = 5) -> dict:
    """Replacement-mode index generation: batched single draw vs the
    PR 1 per-worker python loop."""
    def loop_draw():  # the old implementation, for comparison
        rngs = [np.random.default_rng(10_007 + i) for i in range(workers)]
        out = np.empty((steps, workers, batch), np.int64)
        for t in range(steps):
            for i in range(workers):
                out[t, i] = rngs[i].integers(0, SAMPLES, batch)
        return out

    def block_draw():
        sh = WorkerSharder(SAMPLES, workers, seed=1, mode="replacement")
        return sh.next_index_block(steps, batch)

    out = {}
    for name, fn in (("loop", loop_draw), ("block", block_draw)):
        fn()
        best = min(_timed(fn) for _ in range(reps))
        out[f"sharder_{name}_us"] = best * 1e6
    out["sharder_speedup"] = out["sharder_loop_us"] / out["sharder_block_us"]
    return out


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(tiny: bool = False):
    steps = 64 if tiny else STEPS
    phase_lens = (1, 8) if tiny else PHASE_LENS
    worker_counts = (4,) if tiny else WORKER_COUNTS
    dim, samples = (16, 256) if tiny else (DIM, SAMPLES)
    reps = 1 if tiny else 3

    X, y, _ = convex_dataset("ls", samples, dim, sparsity=0.2, noise=0.1,
                             seed=0)
    w0 = {"w": jnp.zeros(dim)}
    results = []
    for workers in worker_counts:
        rng = np.random.default_rng(0)
        idx = rng.integers(0, samples, size=(steps, workers))
        Xj, yj = jnp.asarray(X), jnp.asarray(y)
        batches = [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(steps)]
        for k in phase_lens:
            # small-K schedules still scan big blocks: averaging decisions
            # are per-step and on-device, so one compiled block may span
            # many averaging periods
            block = max(k, 64)
            tree_eng = make_engine(k, flat=False)
            flat_eng = make_engine(k, flat=True)

            def staged(eng, prefetch):
                return lambda: eng.run(w0, batches, num_workers=workers,
                                       seed=0, phase_len=block,
                                       prefetch=prefetch)

            def indexed():
                ds = DeviceDataset({"x": Xj, "y": yj}, workers, indices=idx)
                return flat_eng.run(w0, ds, num_workers=workers, seed=0,
                                    phase_len=block)

            row = {"workers": workers, "phase_len": k, "steps": steps}
            if not tiny:
                row["host_ms_per_step"] = time_run(
                    lambda: tree_eng.run_host(w0, batches,
                                              num_workers=workers, seed=0),
                    steps, reps=reps)
            row["tree_ms_per_step"] = time_run(
                staged(tree_eng, False), steps, reps=reps)
            row["flat_staged_ms_per_step"] = time_run(
                staged(flat_eng, False), steps, reps=reps)
            row["flat_prefetch_ms_per_step"] = time_run(
                staged(flat_eng, True), steps, reps=reps)
            row["flat_indexed_ms_per_step"] = time_run(
                indexed, steps, reps=reps)
            row["speedup_flat_vs_tree"] = (row["tree_ms_per_step"] /
                                           row["flat_indexed_ms_per_step"])
            row["speedup_prefetch_vs_stack"] = (
                row["flat_staged_ms_per_step"] /
                row["flat_prefetch_ms_per_step"])
            if not tiny:
                row["speedup_vs_host"] = (row["host_ms_per_step"] /
                                          row["flat_indexed_ms_per_step"])
            results.append(row)
            emit(f"engine_K{k}_M{workers}",
                 row["flat_indexed_ms_per_step"] * 1e3,
                 f"tree_ms/step={row['tree_ms_per_step']:.3f};"
                 f"flat_indexed_ms/step={row['flat_indexed_ms_per_step']:.3f};"
                 f"flat_vs_tree={row['speedup_flat_vs_tree']:.2f}x;"
                 f"prefetch_vs_stack={row['speedup_prefetch_vs_stack']:.2f}x")

    sharder = bench_sharder(max(worker_counts), steps)
    emit("sharder_replacement", sharder["sharder_block_us"],
         f"loop_us={sharder['sharder_loop_us']:.0f};"
         f"block_us={sharder['sharder_block_us']:.0f};"
         f"speedup={sharder['sharder_speedup']:.1f}x")

    heavy = [r["speedup_flat_vs_tree"] for r in results
             if r["phase_len"] <= AVG_HEAVY_K]
    print(f"min flat-vs-tree speedup at K<={AVG_HEAVY_K}: {min(heavy):.2f}x")
    if not tiny:
        save("bench_engine", {
            "workload": {"dim": DIM, "samples": SAMPLES, "steps": STEPS,
                         "kind": "ls"},
            "rows": results, "sharder": sharder})
    return results


if __name__ == "__main__":
    run(tiny="--tiny" in sys.argv[1:])

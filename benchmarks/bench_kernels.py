"""Kernel micro-benchmarks: Pallas (interpret on CPU / Mosaic on TPU)
vs the XLA reference path, per shape. On this CPU container the timing
column is indicative only; the derived column reports max|err| vs the
oracle, which is the portable claim."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ref import (flash_attention_ref, rglru_scan_ref,
                               rwkv6_scan_ref)


def run():
    key = jax.random.PRNGKey(0)
    # flash attention
    for (b, s, h, hkv, hd) in [(1, 512, 8, 2, 64), (1, 1024, 4, 1, 128)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
        f = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
        us, out = timeit(lambda: jax.block_until_ready(f(q, k, v)), reps=2)
        ref = flash_attention_ref(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(out - ref)))
        emit(f"kernel_flash_attn_b{b}_s{s}_h{h}kv{hkv}_d{hd}", us,
             f"maxerr_vs_oracle={err:.1e}")
    # rglru
    a = jax.random.uniform(key, (1, 1024, 1024), minval=0.5, maxval=0.999)
    bb = jax.random.normal(key, (1, 1024, 1024)) * 0.1
    us, out = timeit(lambda: jax.block_until_ready(rglru_scan(a, bb)), reps=2)
    err = float(jnp.max(jnp.abs(out - rglru_scan_ref(a, bb))))
    emit("kernel_rglru_scan_s1024_w1024", us, f"maxerr_vs_oracle={err:.1e}")
    # rwkv6
    ks = jax.random.split(key, 5)
    r, k2, v2 = (jax.random.normal(ks[i], (1, 256, 4, 64)) * 0.5
                 for i in range(3))
    lw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (1, 256, 4, 64))),
                  -5.0, -1e-5)
    u = jax.random.normal(ks[4], (256,)) * 0.1
    us, out = timeit(lambda: jax.block_until_ready(
        rwkv6_scan(r, k2, v2, lw, u)), reps=2)
    err = float(jnp.max(jnp.abs(out - rwkv6_scan_ref(r, k2, v2, lw, u))))
    emit("kernel_rwkv6_scan_s256_h4_n64", us, f"maxerr_vs_oracle={err:.1e}")


if __name__ == "__main__":
    run()

"""Paper Figure 2: normalized suboptimality vs iteration for one-shot /
periodic(128) / periodic(1024->scaled) / minibatch averaging + single
worker, on the convex suite; derived speedup@0.1 of periodic vs one-shot
(the paper's speedup column).

All schedules run through the PhaseEngine (one compiled dispatch per
averaging phase) with shared per-step sample draws for a fair, paired
comparison, as the paper shuffles identically. The dataset lives on
device once (DeviceDataset); each phase ships only the shared index
block and gathers batches inside the compiled scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save, timeit
from repro.configs.paper import CONVEX_SUITE
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import DeviceDataset, convex_dataset
from repro.models.convex import lr_objective, ls_objective, solve_optimum
from repro.optim import SGD


def _schedule(phase_len: int) -> AveragingSchedule:
    if phase_len == 0:
        return AveragingSchedule("oneshot")
    if phase_len == 1:
        return AveragingSchedule("minibatch")
    return AveragingSchedule("periodic", phase_len)


def sgd_curves(kind, X, y, *, workers, steps, phase_lens, lr0, lr_d,
               seed=0, record_every=20):
    """Engine-driven multi-schedule parallel SGD (shared sample draws for
    a fair, paired comparison, as the paper shuffles identically)."""
    n, d = X.shape
    obj = {"ls": ls_objective, "lr": lr_objective}[kind]
    obj_j = jax.jit(lambda w: obj(w, X, y))

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(steps, workers))
    w0 = jnp.zeros(d)
    f0 = float(obj_j(w0))
    fstar = float(obj_j(solve_optimum(kind, X, y)))

    def loss_fn(params, batch, rng_):
        w, x, yv = params["w"], batch["x"], batch["y"]
        if kind == "ls":
            return 0.5 * jnp.square(x @ w - yv), {}
        return jax.nn.softplus(-yv * (x @ w)), {}

    # the paper's lr schedule counts steps from 0; engine steps are
    # 1-indexed, hence the -1
    opt = SGD(lr=lambda t: lr0 / (t - 1.0 + lr_d))

    # device_put the dataset once for all schedules/worker counts; the
    # per-curve DeviceDataset wraps these committed arrays without copying
    arrays = {"x": jax.device_put(X), "y": jax.device_put(y)}

    def curve(schedule, m):
        engine = PhaseEngine(loss_fn, opt, schedule)
        # paired draws: worker w of every schedule sees idx[:, w]; the
        # (steps, m) index list is gathered on-device inside the scan
        ds = DeviceDataset(arrays, m, indices=idx[:, :m])
        _, hist = engine.run({"w": w0}, ds, num_workers=m,
                             seed=seed, record_every=record_every,
                             eval_fn=lambda p: float(obj_j(p["w"])))
        return hist["eval"]

    curves = {}
    for k in phase_lens:
        name = {0: "oneshot", 1: "minibatch"}.get(k, f"periodic_{k}")
        curves[name] = curve(_schedule(k), workers)

    # single worker curve (worker 0's draws, no averaging)
    curves["single"] = curve(AveragingSchedule("oneshot"), 1)

    # normalize so f(w0)=1, f*=0
    span = max(f0 - fstar, 1e-12)
    for name in curves:
        curves[name] = [(t, (v - fstar) / span) for t, v in curves[name]]
    return curves


def _steps_to(curve, level):
    for t, v in curve:
        if v <= level:
            return t
    return float("inf")


def grid_curves(kind, X, y, *, workers=8, steps=3000,
                phase_lens=(0, 1, 128, 1024),
                lr_mults=(0.4, 0.8, 1.6, 3.0, 6.0), lr_d=200.0):
    """The paper's protocol: grid-search the lr schedule and report, at
    each iteration, the minimum objective over the grid (per schedule).
    This is what surfaces the averaging speedup — frequent averaging
    tolerates (and exploits) aggressive step sizes that make independent
    workers diverge transiently."""
    meansq = float(jnp.mean(jnp.sum(X * X, axis=1)))
    best = None
    for mult in lr_mults:
        cur = sgd_curves(kind, X, y, workers=workers, steps=steps,
                         phase_lens=list(phase_lens),
                         lr0=mult * lr_d / meansq, lr_d=lr_d)
        if best is None:
            best = cur
        else:
            for name in cur:
                best[name] = [(t, min(a, b)) for (t, a), (_, b)
                              in zip(best[name], cur[name])]
    return best


def run():
    all_out = {}
    total_us = 0.0
    for c in CONVEX_SUITE:
        n = min(c.num_samples, 2048)
        d = min(c.num_dims, 256)
        X, y, _ = convex_dataset(c.model, n, d, sparsity=c.sparsity,
                                 noise=c.noise, seed=0)
        X, y = jnp.asarray(X), jnp.asarray(y)
        dt, curves = timeit(lambda: grid_curves(c.model, X, y), reps=1)
        total_us += dt
        s_per = _steps_to(curves["periodic_128"], 0.1)
        s_one = _steps_to(curves["oneshot"], 0.1)
        speedup = s_one / s_per if np.isfinite(s_per) else float("inf")
        final_gap = (curves["oneshot"][-1][1] /
                     max(curves["periodic_128"][-1][1], 1e-15))
        all_out[c.name] = {"curves": curves, "speedup_at_0.1": speedup,
                           "final_subopt_ratio": final_gap}
    save("bench_fig2_convex", all_out)
    emit("fig2_convex_curves", total_us,
         ";".join(f"{k}:speedup@0.1={v['speedup_at_0.1']:.2f},"
                  f"final_ratio={v['final_subopt_ratio']:.1f}"
                  for k, v in all_out.items()))


if __name__ == "__main__":
    run()

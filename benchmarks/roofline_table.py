"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR

HDR = ("| arch | shape | mesh | avg | variant | flops/dev | bytes/dev | "
       "coll B/dev | compute s | memory s | coll s | bound | "
       "useful-FLOP frac |")
SEP = "|" + "---|" * 13


def fmt_row(r):
    def e(x):
        return f"{x:.2e}" if isinstance(x, (int, float)) else "-"
    if "skipped" in r:
        return (f"| {r.get('arch','?')} | {r.get('shape','?')} | "
                f"{r.get('mesh','-')} | - | - | SKIP | {r['skipped']} "
                f"| | | | | | |")
    return ("| {arch} | {shape} | {mesh} | {avg} | {var} | {f} | {b} | {c} "
            "| {cs} | {ms} | {cls} | **{bn}** | {uf} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        avg=r.get("avg", "none"), var=r.get("variant", "baseline"),
        f=e(r.get("flops_per_device")), b=e(r.get("bytes_per_device")),
        c=e(r.get("collective_bytes_per_device")),
        cs=f"{r.get('compute_s', 0):.4f}", ms=f"{r.get('memory_s', 0):.4f}",
        cls=f"{r.get('collective_s', 0):.4f}", bn=r.get("bottleneck", "?"),
        uf=(f"{r['useful_flop_fraction']:.2f}"
            if r.get("useful_flop_fraction") else "-"))


def load(path=None):
    path = path or os.path.join(RESULTS_DIR, "dryrun.jsonl")
    rows, seen = [], set()
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            r = json.loads(line)
        except Exception:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("avg", "none"), r.get("variant", "baseline"))
        if key in seen:
            continue
        seen.add(key)
        rows.append(r)
    return rows


def render(rows=None):
    rows = rows if rows is not None else load()
    out = [HDR, SEP]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r.get("arch", ""),
                                       order.get(r.get("shape", ""), 9),
                                       r.get("mesh", ""),
                                       r.get("avg", "none")))
    for r in rows:
        out.append(fmt_row(r))
    return "\n".join(out)


def run():
    rows = load()
    n_ok = sum(1 for r in rows if "skipped" not in r)
    n_skip = sum(1 for r in rows if "skipped" in r)
    print(f"roofline_table,0.0,combos_compiled={n_ok};skipped={n_skip}")


if __name__ == "__main__":
    print(render())

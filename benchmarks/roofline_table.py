"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables,
plus the analytic roofline of the fused avg_disp kernel (one averaging
event over the flat (M, P) plane)."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR
from repro.roofline import HW

HDR = ("| arch | shape | mesh | avg | variant | flops/dev | bytes/dev | "
       "coll B/dev | compute s | memory s | coll s | bound | "
       "useful-FLOP frac |")
SEP = "|" + "---|" * 13


def fmt_row(r):
    def e(x):
        return f"{x:.2e}" if isinstance(x, (int, float)) else "-"
    if "skipped" in r:
        return (f"| {r.get('arch','?')} | {r.get('shape','?')} | "
                f"{r.get('mesh','-')} | - | - | SKIP | {r['skipped']} "
                f"| | | | | | |")
    return ("| {arch} | {shape} | {mesh} | {avg} | {var} | {f} | {b} | {c} "
            "| {cs} | {ms} | {cls} | **{bn}** | {uf} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        avg=r.get("avg", "none"), var=r.get("variant", "baseline"),
        f=e(r.get("flops_per_device")), b=e(r.get("bytes_per_device")),
        c=e(r.get("collective_bytes_per_device")),
        cs=f"{r.get('compute_s', 0):.4f}", ms=f"{r.get('memory_s', 0):.4f}",
        cls=f"{r.get('collective_s', 0):.4f}", bn=r.get("bottleneck", "?"),
        uf=(f"{r['useful_flop_fraction']:.2f}"
            if r.get("useful_flop_fraction") else "-"))


def load(path=None):
    path = path or os.path.join(RESULTS_DIR, "dryrun.jsonl")
    rows, seen = [], set()
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            r = json.loads(line)
        except Exception:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("avg", "none"), r.get("variant", "baseline"))
        if key in seen:
            continue
        seen.add(key)
        rows.append(r)
    return rows


def render(rows=None):
    rows = rows if rows is not None else load()
    out = [HDR, SEP]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r.get("arch", ""),
                                       order.get(r.get("shape", ""), 9),
                                       r.get("mesh", ""),
                                       r.get("avg", "none")))
    for r in rows:
        out.append(fmt_row(r))
    return "\n".join(out)


def avg_disp_roofline(m: int, p: int, *, groups: int = 1,
                      outer: bool = False, hw: HW = HW()) -> dict:
    """Bytes / FLOPs of ONE fused averaging event on the (M, P) f32
    plane (repro.kernels.avg_disp), vs the tree path's 3-4 passes.

    Reads: the plane (M·P·4 B) once (+ prev_avg & velocity, 2·P·4 B,
    with the outer optimizer); writes: the broadcast plane (+ new
    avg/velocity). FLOPs: mean (M adds + 1 mul per column, + group
    means), dispersion (sub+mul+add per element), outer step (~5/col).
    The kernel is memory-bound at every realistic (M, P) — one averaging
    event costs two sweeps of the plane, where the tree path pays 3-4.
    """
    elems = m * p
    read_b = 4 * (elems + (2 * p if outer else 0))
    write_b = 4 * (elems + (2 * p if outer else 0))
    mean_f = elems + p + (elems + groups * p if groups > 1 else 0)
    disp_f = 3 * elems + p
    outer_f = 5 * p if outer else 0
    flops = mean_f + disp_f + outer_f
    bytes_total = read_b + write_b
    return {
        "kernel": "avg_disp" + ("_outer" if outer else ""),
        "m": m, "p": p, "groups": groups,
        "flops": flops, "bytes": bytes_total,
        "intensity_flop_per_byte": flops / bytes_total,
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_total / hw.hbm_bw,
        "bound": "memory",  # intensity ~0.5 F/B << machine balance
        "tree_path_passes": 4 if outer else 3,
        "fused_passes": 2,
    }


def opt_step_roofline(m: int, p: int, *, kind: str = "momentum",
                      mode: str = "mean", wire: str = "f32",
                      hw: HW = HW()) -> dict:
    """Bytes / FLOPs of ONE fused opt_step pass (repro.kernels.opt_step):
    local optimizer update on the (M, P) plane + S state planes, plus
    the worker mean + Eq. 4 dispersion in EVERY mode (the always-on
    dispersion that drives the adaptive schedules and the per-step
    trace), and the broadcast on averaging steps (mode != "none").

    Reads: param plane + grad plane + S state planes; writes: param
    plane + S state planes (each M·P·4 B). FLOPs per element: sgd 2
    (fma), momentum 4, adamw ~12 (incl. div/sqrt), + ~4 for the
    mean/dispersion reduction (all modes — it rides the same sweep, so
    the always-on measurement adds no memory traffic). The un-fused
    path pays an extra read+write sweep of the plane for the optimizer
    update before the avg_disp pass (3 sweeps on averaging steps;
    tree-path optimizers additionally traverse every leaf).

    mode "mix" is the gossip-topology event (repro.topology): the
    (M, M) @ (M, P) mixing contraction adds 2M FLOPs per plane element
    and one M·M·4 B read of W — negligible traffic against the plane
    sweep (M is 4–64), so the mix stays memory-bound on the SAME
    single pass: the topology axis is free in bytes, paid only in
    (cheap) MXU flops.

    ``wire`` prices the event's BYTES ON THE WIRE (what a multi-host
    deployment ships between chips — M encoded rows per event,
    ``repro.core.compress.wire_row_bytes``) at that format. The
    compressed event's encode/decode/error-feedback adds ~6 FLOPs +
    one extra residual read+write sweep per element, but the wire
    payload shrinks by WIRE_BITS/32 — int8 moves ~4x fewer bytes over
    the links for an extra memory-bound plane sweep, which is exactly
    the trade a collective-bound step wants."""
    from repro.core.compress import wire_row_bytes
    s = {"sgd": 0, "momentum": 1, "adamw": 2}[kind]
    upd_f = {"sgd": 2, "momentum": 4, "adamw": 12}[kind]
    mix = mode == "mix"
    comp = wire != "f32"
    elems = m * p
    # compressed events read + write the (M, P) error-feedback residual
    # plane alongside the param plane
    read_b = 4 * (elems * (2 + s + (1 if comp else 0))
                  + (m * m if mix else 0))
    write_b = 4 * elems * (1 + s + (1 if comp else 0))
    # encode (scale + round) + decode + residual update: ~6 flops/elem
    flops = (upd_f * elems + 4 * elems + 2 * p
             + (2 * m * elems if mix else 0)
             + (6 * elems if comp else 0))
    bytes_total = read_b + write_b
    wire_b = m * wire_row_bytes(p, wire)
    return {
        "kernel": f"opt_step[{kind},{mode}"
                  + (f",{wire}]" if comp else "]"),
        "m": m, "p": p, "state_planes": s, "wire": wire,
        "flops": flops, "bytes": bytes_total,
        "intensity_flop_per_byte": flops / bytes_total,
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_total / hw.hbm_bw,
        "wire_bytes_per_event": wire_b,
        "wire_reduction_vs_f32": (m * wire_row_bytes(p, "f32")) / wire_b,
        "bound": "memory",  # intensity << machine balance even at M=64
        "unfused_passes": 3 if mode != "none" else 2,
        "fused_passes": 1,
    }


AVG_DISP_HDR = ("| kernel | M | P | groups | FLOPs | bytes | F/B | "
                "memory s | passes (tree -> fused) |")
AVG_DISP_SEP = "|" + "---|" * 9

OPT_STEP_HDR = ("| kernel | M | P | S | FLOPs | bytes | F/B | memory s | "
                "wire B/event | wire vs f32 | passes (unfused -> fused) |")
OPT_STEP_SEP = "|" + "---|" * 11


def render_opt_step(cases=(("sgd", "none"), ("momentum", "none"),
                           ("momentum", "mean"), ("momentum", "mix"),
                           ("momentum", "mean", "int8"),
                           ("momentum", "mix", "int8"),
                           ("momentum", "mix", "one_bit"),
                           ("adamw", "mean")),
                    m: int = 16, p: int = 1 << 20) -> str:
    out = [OPT_STEP_HDR, OPT_STEP_SEP]
    for case in cases:
        kind, mode, wire = (*case, "f32")[:3]
        r = opt_step_roofline(m, p, kind=kind, mode=mode, wire=wire)
        out.append(
            f"| {r['kernel']} | {m} | {p} | {r['state_planes']} | "
            f"{r['flops']:.2e} | {r['bytes']:.2e} | "
            f"{r['intensity_flop_per_byte']:.2f} | {r['memory_s']:.2e} | "
            f"{r['wire_bytes_per_event']:.2e} | "
            f"{r['wire_reduction_vs_f32']:.2f}x | "
            f"{r['unfused_passes']} -> {r['fused_passes']} |")
    return "\n".join(out)


def render_avg_disp(cases=((16, 1 << 20, 1, False), (16, 1 << 20, 4, False),
                           (16, 1 << 20, 1, True),
                           (64, 1 << 24, 1, True))) -> str:
    out = [AVG_DISP_HDR, AVG_DISP_SEP]
    for m, p, groups, outer in cases:
        r = avg_disp_roofline(m, p, groups=groups, outer=outer)
        out.append(
            f"| {r['kernel']} | {m} | {p} | {groups} | {r['flops']:.2e} | "
            f"{r['bytes']:.2e} | {r['intensity_flop_per_byte']:.2f} | "
            f"{r['memory_s']:.2e} | {r['tree_path_passes']} -> "
            f"{r['fused_passes']} |")
    return "\n".join(out)


def run():
    rows = load()
    n_ok = sum(1 for r in rows if "skipped" not in r)
    n_skip = sum(1 for r in rows if "skipped" in r)
    r = avg_disp_roofline(16, 1 << 20)
    o = opt_step_roofline(16, 1 << 20, kind="momentum", mode="mean")
    print(f"roofline_table,0.0,combos_compiled={n_ok};skipped={n_skip};"
          f"avg_disp_fb={r['intensity_flop_per_byte']:.2f};"
          f"opt_step_fb={o['intensity_flop_per_byte']:.2f}")


if __name__ == "__main__":
    print(render())
    print()
    print(render_avg_disp())
    print()
    print(render_opt_step())

"""Benchmark driver — one benchmark per paper table/figure + kernel
micro-benches + roofline summary. Prints ``name,us_per_call,derived``
CSV lines (spec format) and saves full payloads under results/."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (averaging_cost, bench_fig1_pca,
                            bench_fig2_convex, bench_fig3_cnn,
                            bench_kernels, bench_lemma1, bench_quartic,
                            bench_table1, roofline_table)
    benches = [
        ("lemma1 (paper §2.3)", bench_lemma1),
        ("table1 (paper Table 1)", bench_table1),
        ("fig2 convex (paper Fig 2)", bench_fig2_convex),
        ("fig1 pca (paper Fig 1)", bench_fig1_pca),
        ("quartic (paper §2.4)", bench_quartic),
        ("fig3 cnn (paper Fig 3 / §3.2)", bench_fig3_cnn),
        ("kernels", bench_kernels),
        ("averaging cost (paper's trade-off, from dry-run)", averaging_cost),
        ("roofline (EXPERIMENTS.md §Roofline)", roofline_table),
    ]
    print("name,us_per_call,derived")
    failed = []
    for label, mod in benches:
        try:
            mod.run()
        except Exception:
            failed.append(label)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

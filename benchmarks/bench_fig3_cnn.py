"""Paper Figure 3: CNN (LeNet5-like) on MNIST-like data — one-shot vs
periodic (phase 10) vs best/worst single worker; momentum SGD lr .01,
mu .9, x0.95/epoch, 4 workers, batch 8 (the paper's exact recipe, with
a reduced step budget for the CPU container). Both schedules run through
the PhaseEngine — one compiled dispatch per averaging phase, per-worker
metrics fetched only at record boundaries. The image set is device-put
ONCE (DeviceDataset); each phase ships a (K, M, B) index block from the
per-worker permutation sharder and gathers batches inside the scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save, timeit
from repro.configs.paper import CNNConfig
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import mnist_like
from repro.data.pipeline import DeviceDataset
from repro.models.cnn import cnn_error, cnn_loss, init_cnn
from repro.optim import Momentum, schedules


def run_cnn(cfg: CNNConfig, steps: int, *, seed=0, record_every=25,
            eval_n=512, noise=0.6):
    # high sample noise so the task is not instantly memorizable and the
    # averaging-schedule differences are visible (paper Fig 3 regime)
    images, labels = mnist_like(4096, seed=seed, noise=noise)
    test_images, test_labels = mnist_like(eval_n, seed=seed + 1, noise=noise)
    M = cfg.num_workers
    params0 = init_cnn(cfg, jax.random.PRNGKey(seed))
    # ONE dataset + sharder shared by both schedule runs (the second run
    # continues the permutation cursors, as the host-staged loop did)
    dataset = DeviceDataset({"images": images, "labels": labels}, M,
                            batch_size=cfg.batch_size, seed=seed,
                            mode="permute")
    steps_per_epoch = len(images) // (M * cfg.batch_size)
    # the paper's epoch decay counts steps from 0; engine steps are
    # 1-indexed, hence the -1
    epoch_lr = schedules.exponential_epoch(cfg.lr, cfg.lr_decay_per_epoch,
                                           steps_per_epoch)
    opt = Momentum(lr=lambda step: epoch_lr(step - 1), mu=cfg.momentum)

    def loss_fn(p, batch, rng):
        return cnn_loss(cfg, p, batch), {}

    @jax.jit
    def full_metrics(p):
        tr = cnn_loss(cfg, p, {"images": jnp.asarray(images[:eval_n]),
                               "labels": jnp.asarray(labels[:eval_n])})
        te = cnn_error(cfg, p, {"images": jnp.asarray(test_images),
                                "labels": jnp.asarray(test_labels)})
        return tr, te

    def eval_consensus(p):
        tr, te = full_metrics(p)
        return float(tr), float(te)

    def eval_workers(wp):
        trs = [float(full_metrics(jax.tree.map(lambda x: x[i], wp))[0])
               for i in range(M)]
        return min(trs), max(trs)

    def run_schedule(phase_len):
        sch = (AveragingSchedule("periodic", phase_len) if phase_len
               else AveragingSchedule("oneshot"))
        # phase blocks = record period: averaging decisions are per-step
        # and on-device, so one block can span several averaging phases —
        # and every dispatch then compiles a single (K=25) scan shape.
        # scan_unroll=True: conv-heavy body on the CPU container (XLA:CPU
        # under-threads rolled while-loop bodies)
        engine = PhaseEngine(loss_fn, opt, sch, scan_unroll=True)
        _, hist = engine.run(params0, dataset, num_workers=M, seed=seed,
                             record_every=record_every,
                             eval_fn=eval_consensus,
                             worker_eval_fn=eval_workers,
                             phase_len=record_every, steps=steps)
        return {"avg": [(t, tr, te) for t, (tr, te) in hist["eval"]],
                "best": [(t, lo) for t, (lo, _) in hist["worker_eval"]],
                "worst": [(t, hi) for t, (_, hi) in hist["worker_eval"]]}

    return {"periodic": run_schedule(cfg.phase_len),
            "oneshot": run_schedule(0)}


def run():
    cfg = CNNConfig()
    dt, out = timeit(lambda: run_cnn(cfg, steps=200), reps=1)
    save("bench_fig3_cnn", out)
    p_final, p_err = out["periodic"]["avg"][-1][1:]
    o_final, o_err = out["oneshot"]["avg"][-1][1:]
    o_worst = out["oneshot"]["worst"][-1][1]
    p_best = out["periodic"]["best"][-1][1]
    emit("fig3_cnn_mnist", dt,
         f"periodic_loss={p_final:.3f}(err={p_err:.3f});"
         f"oneshot_loss={o_final:.3f}(err={o_err:.3f});"
         f"oneshot_worse_than_worst_worker={o_final > o_worst};"
         f"periodic_beats_best_worker={p_final <= p_best + 1e-6}")


if __name__ == "__main__":
    run()

"""Paper Figure 3: CNN (LeNet5-like) on MNIST-like data — one-shot vs
periodic (phase 10) vs best/worst single worker; momentum SGD lr .01,
mu .9, x0.95/epoch, 4 workers, batch 8 (the paper's exact recipe, with
a reduced step budget for the CPU container)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save, timeit
from repro.configs.paper import CNNConfig
from repro.data import mnist_like
from repro.data.pipeline import WorkerSharder
from repro.models.cnn import cnn_error, cnn_forward, cnn_loss, init_cnn
from repro.optim import Momentum, schedules


def run_cnn(cfg: CNNConfig, steps: int, *, seed=0, record_every=25,
            eval_n=512, noise=0.6):
    # high sample noise so the task is not instantly memorizable and the
    # averaging-schedule differences are visible (paper Fig 3 regime)
    images, labels = mnist_like(4096, seed=seed, noise=noise)
    test_images, test_labels = mnist_like(eval_n, seed=seed + 1, noise=noise)
    M = cfg.num_workers
    params0 = init_cnn(cfg, jax.random.PRNGKey(seed))
    sharder = WorkerSharder(len(images), M, seed=seed, mode="permute")
    steps_per_epoch = len(images) // (M * cfg.batch_size)
    opt = Momentum(lr=schedules.exponential_epoch(
        cfg.lr, cfg.lr_decay_per_epoch, steps_per_epoch), mu=cfg.momentum)

    @jax.jit
    def wstep(wp, wos, imgs, labs, t):
        def upd(p, s, im, lb):
            loss, g = jax.value_and_grad(
                lambda pp: cnn_loss(cfg, pp, {"images": im, "labels": lb}))(p)
            p2, s2 = opt.apply(p, g, s, t)
            return p2, s2, loss
        return jax.vmap(upd)(wp, wos, imgs, labs)

    @jax.jit
    def full_metrics(p):
        tr = cnn_loss(cfg, p, {"images": jnp.asarray(images[:eval_n]),
                               "labels": jnp.asarray(labels[:eval_n])})
        te = cnn_error(cfg, p, {"images": jnp.asarray(test_images),
                                "labels": jnp.asarray(test_labels)})
        return tr, te

    def run_schedule(phase_len):
        wp = jax.tree.map(lambda x: jnp.stack([x] * M), params0)
        wos = jax.vmap(opt.init)(wp)
        rec = {"avg": [], "best": [], "worst": []}
        for t in range(steps):
            idx = sharder.next_indices(cfg.batch_size)
            imgs = jnp.asarray(images[idx])
            labs = jnp.asarray(labels[idx])
            wp, wos, losses = wstep(wp, wos, imgs, labs,
                                    jnp.asarray(t, jnp.float32))
            if phase_len and (t + 1) % phase_len == 0:
                wp = jax.tree.map(
                    lambda x: jnp.broadcast_to(x.mean(0), x.shape), wp)
            if (t + 1) % record_every == 0:
                avg = jax.tree.map(lambda x: x.mean(0), wp)
                tr, te = full_metrics(avg)
                rec["avg"].append((t + 1, float(tr), float(te)))
                per = [full_metrics(jax.tree.map(lambda x: x[i], wp))
                       for i in range(M)]
                trs = [float(a) for a, _ in per]
                rec["best"].append((t + 1, min(trs)))
                rec["worst"].append((t + 1, max(trs)))
        return rec

    return {"periodic": run_schedule(cfg.phase_len),
            "oneshot": run_schedule(0)}


def run():
    cfg = CNNConfig()
    dt, out = timeit(lambda: run_cnn(cfg, steps=200), reps=1)
    save("bench_fig3_cnn", out)
    p_final, p_err = out["periodic"]["avg"][-1][1:]
    o_final, o_err = out["oneshot"]["avg"][-1][1:]
    o_worst = out["oneshot"]["worst"][-1][1]
    p_best = out["periodic"]["best"][-1][1]
    emit("fig3_cnn_mnist", dt,
         f"periodic_loss={p_final:.3f}(err={p_err:.3f});"
         f"oneshot_loss={o_final:.3f}(err={o_err:.3f});"
         f"oneshot_worse_than_worst_worker={o_final > o_worst};"
         f"periodic_beats_best_worker={p_final <= p_best + 1e-6}")


if __name__ == "__main__":
    run()

"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timeit(fn, *args, reps: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / reps
    return dt * 1e6, out  # microseconds


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")

"""Paper §2.4 quartic example: f(w) = (w²-1)², ∇f̃ = 4(w³-w+u).
The paper reports (24 workers, α=.025, 10000 steps): one-shot 0.922,
0.1%% averaging 0.274, 10%% averaging 0.011."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save, timeit
from repro.configs.paper import QuarticConfig


def run_quartic(cfg: QuarticConfig, avg_fracs, seed=0):
    key = jax.random.PRNGKey(seed)
    u = jax.random.normal(key, (cfg.num_steps, cfg.num_workers))
    rows = []
    for frac in avg_fracs:
        k = 0 if frac == 0 else max(1, int(round(1.0 / frac)))
        do_avg = (jnp.arange(1, cfg.num_steps + 1) % k == 0) if k else \
            jnp.zeros(cfg.num_steps, bool)

        @jax.jit
        def go():
            def body(w, inp):
                ut, at = inp
                g = 4.0 * (w ** 3 - w + ut)
                w = w - cfg.alpha * g
                w = jnp.where(at, jnp.full_like(w, jnp.mean(w)), w)
                return w, None
            w, _ = jax.lax.scan(body, jnp.zeros(cfg.num_workers),
                                (u, do_avg))
            return jnp.mean(w)

        wbar = float(go())
        obj = (wbar ** 2 - 1.0) ** 2
        rows.append({"avg_frac": frac, "objective": float(obj)})
    return rows


def run():
    cfg = QuarticConfig()
    dt, rows = timeit(lambda: run_quartic(cfg, [0.0, 0.001, 0.01, 0.1]),
                      reps=1)
    save("bench_quartic", {"rows": rows,
                           "paper": {"oneshot": 0.922, "0.001": 0.274,
                                     "0.1": 0.011}})
    d = {r["avg_frac"]: r["objective"] for r in rows}
    emit("quartic_nonconvex", dt,
         f"oneshot={d[0.0]:.3f};avg0.1%={d[0.001]:.3f};avg10%={d[0.1]:.3f}")


if __name__ == "__main__":
    run()

"""Paper §2.3 / Lemma 1: asymptotic variance of the worker average vs
averaging rate ζ — closed form against Monte-Carlo simulation."""
from __future__ import annotations


from benchmarks.common import emit, save, timeit
from repro.configs.paper import QuadraticConfig
from repro.core.theory import lemma1_asymptotic_variance, simulate_quadratic


def run():
    cfg = QuadraticConfig()
    zetas = [0.0, 0.001, 0.005, 0.02, 0.1, 0.3, 1.0]
    rows = []
    us = 0.0
    for z in zetas:
        pred = lemma1_asymptotic_variance(cfg.alpha, cfg.c, cfg.beta2,
                                          cfg.sigma2, cfg.num_workers, z)
        dt, sim = timeit(simulate_quadratic, cfg.alpha, cfg.c, cfg.beta2,
                         cfg.sigma2, cfg.num_workers, z, 3000, reps=1)
        us += dt
        rows.append({"zeta": z, "lemma1": pred, "simulated": float(sim),
                     "rel_err": abs(float(sim) - pred) / pred})
    worst = max(r["rel_err"] for r in rows)
    ratio = rows[0]["lemma1"] / rows[-1]["lemma1"]
    save("bench_lemma1", {"rows": rows, "config": cfg.__dict__})
    emit("lemma1_asymptotic_variance", us,
         f"worst_rel_err={worst:.3f};oneshot/minibatch_var_ratio={ratio:.3f}")


if __name__ == "__main__":
    run()

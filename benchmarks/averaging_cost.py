"""The paper's statistical/hardware-efficiency trade-off, quantified from
dry-run artifacts: averaging cost per phase, amortized per-step overhead
vs phase length K, and the break-even K where communication drops below
x% of step time.

Reads (arch, train_4k) rows from results/dryrun.jsonl: the `avg=none`
row gives the pure local step; the `avg=all` row adds the phase-end
model average. The difference in collective bytes is the cost of one
averaging operation (the paper's "communication cost of a phase").
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, emit, save
from repro.roofline.analysis import HW


def load_pairs(path=None):
    path = path or os.path.join(RESULTS_DIR, "dryrun.jsonl")
    rows = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except Exception:
            continue
        if r.get("shape") != "train_4k" or "skipped" in r:
            continue
        if r.get("variant", "baseline") != "baseline":
            continue
        key = (r["arch"], r["mesh"], r.get("avg", "none"))
        rows[key] = r
    return rows


def analyze(hw: HW = HW()):
    rows = load_pairs()
    out = []
    for (arch, mesh, avg), r in sorted(rows.items()):
        if avg == "none":
            continue
        base = rows.get((arch, mesh, "none"))
        if base is None:
            continue
        if r.get("phase_steps", 1) != base.get("phase_steps", 1):
            # rows from different dry-run generations / --phase-steps:
            # their collective-bytes deltas are not comparable
            continue
        d_coll = (r["collective_bytes_per_device"]
                  - base["collective_bytes_per_device"])
        # analytic cost of one model average: all-reduce of the per-chip
        # param shard (bf16, 16-way model sharding) ~ 2x payload on a ring.
        from repro.configs import get_config
        n_params = get_config(arch).num_params()
        analytic_bytes = 2.0 * n_params * 2 / 16
        # XLA CSEs the phase-end all-reduce into the step's existing
        # FSDP gathers when measured; report max(measured, analytic).
        avg_s = max(d_coll, analytic_bytes) / hw.ici_bw
        # train rows are whole compiled phases (phase_steps local steps);
        # normalize to per-step time for the amortization analysis
        k_phase = max(base.get("phase_steps", 1), 1)
        step_s = max(base["compute_s"], base["memory_s"],
                     base["collective_s"]) / k_phase
        ks = {}
        for frac in (0.01, 0.05, 0.25):
            ks[f"K_for_{int(frac*100)}pct"] = (
                max(1, round(avg_s / (step_s * frac))) if step_s else None)
        out.append({
            "arch": arch, "mesh": mesh, "avg": avg,
            "avg_bytes_per_device": max(d_coll, analytic_bytes),
            "measured_coll_delta_bytes": d_coll,
            "avg_seconds": avg_s,
            "local_step_seconds": step_s,
            "minibatch_overhead_pct": 100.0 * avg_s / step_s if step_s else None,
            **ks,
        })
    return out


def run():
    out = analyze()
    save("averaging_cost", {"rows": out})
    if out:
        emit("averaging_cost_amortization", 0.0,
             ";".join(f"{r['arch']}:avg={r['avg_seconds']:.3f}s,"
                      f"K1%={r['K_for_1pct']}" for r in out[:6]))
    else:
        emit("averaging_cost_amortization", 0.0, "no avg rows yet")


if __name__ == "__main__":
    run()

"""Regenerate the generated sections of EXPERIMENTS.md from results/:
the §Roofline table and the averaging-cost table. Idempotent."""
from __future__ import annotations

import os
import re

from benchmarks.averaging_cost import analyze
from benchmarks.roofline_table import load, render

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MD = os.path.join(ROOT, "EXPERIMENTS.md")

ROOF_BEGIN = "<!-- ROOFLINE_TABLE -->"
ROOF_END = "<!-- /ROOFLINE_TABLE -->"
AVG_BEGIN = "<!-- AVG_COST -->"
AVG_END = "<!-- /AVG_COST -->"


def _splice(text, begin, end, payload):
    block = f"{begin}\n{payload}\n{end}"
    if end in text:
        return re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    return text.replace(begin, block)


def avg_table():
    rows = analyze()
    if not rows:
        return "(averaging-cost rows pending — rerun after the avg sweep)"
    out = ["| arch | mesh | avg scope | avg bytes/dev | avg s | local step s "
           "| minibatch (K=1) overhead | K for ≤1% | K for ≤5% |",
           "|" + "---|" * 9]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['mesh']} | {r['avg']} | "
            f"{r['avg_bytes_per_device']:.2e} | {r['avg_seconds']:.3f} | "
            f"{r['local_step_seconds']:.3f} | "
            f"{r['minibatch_overhead_pct']:.1f}% | {r['K_for_1pct']} | "
            f"{r['K_for_5pct']} |")
    out.append("")
    out.append(
        "Reading: `avg s` is the cost of ONE model-average (the paper's "
        "phase-end step) on the worker axis — analytic 2·params/chip "
        "bytes over ICI, used because the *measured* collective delta "
        "between the avg=all and avg=none compilations is ≈0: XLA CSEs "
        "the phase-end all-reduce into the step's existing FSDP "
        "all-gather traffic (a genuinely useful systems finding — on an "
        "FSDP-sharded mesh the paper's averaging step is nearly free at "
        "the HLO level). Amortized per-step overhead is avg_s/K; K=1 "
        "reproduces minibatch averaging (overhead column); the K columns "
        "give the phase length at which averaging communication becomes "
        "negligible — the hardware-efficiency side of the paper's "
        "trade-off, per architecture. The statistical side (how large K "
        "may be before convergence suffers) is governed by ρ "
        "(§Paper-validation): large ρ ⇒ keep K small ⇒ pay the overhead; "
        "small ρ ⇒ one-shot is fine.")
    return "\n".join(out)


def main():
    text = open(MD).read()
    rows = load()
    n_ok = sum(1 for r in rows if "skipped" not in r)
    n_skip = sum(1 for r in rows if "skipped" in r)
    table = (f"{n_ok} combination rows compiled "
             f"({n_skip} recorded skips).\n\n" + render(rows))
    text = _splice(text, ROOF_BEGIN, ROOF_END, table)
    text = _splice(text, AVG_BEGIN, AVG_END, avg_table())
    with open(MD, "w") as f:
        f.write(text)
    print(f"EXPERIMENTS.md updated: {n_ok} roofline rows")


if __name__ == "__main__":
    main()

"""Paper Figure 1: PCA (Oja's rule) principal-component error vs total
number of averaging steps — one-shot (leftmost point) through frequent."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save, timeit
from repro.configs.paper import PCAConfig


def pca_error_vs_avg_steps(cfg: PCAConfig, phase_lens, seed=0):
    spec = np.full(cfg.dim, cfg.tail_eig)
    spec[0] = cfg.top_eig
    v1 = np.eye(cfg.dim)[0]
    rows = []
    for k in phase_lens:
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((cfg.num_workers, cfg.dim))
        w /= np.linalg.norm(w, axis=1, keepdims=True)
        rs = np.random.default_rng(1234)
        n_avg = 0
        for t in range(cfg.num_samples):
            x = rs.standard_normal((cfg.num_workers, cfg.dim)) * np.sqrt(spec)
            wx = np.einsum("md,md->m", w, x)
            w = w + cfg.alpha * wx[:, None] * x
            w /= np.maximum(np.linalg.norm(w, axis=1, keepdims=True), 1e-9)
            if k and (t + 1) % k == 0:
                w = np.broadcast_to(w.mean(0), w.shape).copy()
                w /= np.maximum(np.linalg.norm(w, axis=1, keepdims=True), 1e-9)
                n_avg += 1
        wbar = w.mean(0)
        err = 1.0 - abs(wbar @ v1) / (np.linalg.norm(wbar) + 1e-12)
        rows.append({"phase_len": k, "num_avg_steps": n_avg + 1,
                     "pc_error": float(err)})
    return rows


def run():
    cfg = PCAConfig(num_workers=24, num_samples=4000, alpha=0.02)
    dt, rows = timeit(
        lambda: pca_error_vs_avg_steps(cfg, [0, 2000, 500, 100, 25, 5]),
        reps=1)
    save("bench_fig1_pca", {"rows": rows, "config": cfg.__dict__})
    one = rows[0]["pc_error"]
    best = min(r["pc_error"] for r in rows[1:])
    emit("fig1_pca_oja", dt, f"oneshot_err={one:.3f};best_periodic_err={best:.3f}")


if __name__ == "__main__":
    run()

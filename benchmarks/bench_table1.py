"""Paper Table 1: per-dataset gradient-variance measurements (σ², β², ρ)
via the §3.1 procedure, on the synthetic convex suite (regime analogues
of the paper's libsvm datasets — see DESIGN.md §6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save, timeit
from repro.configs.paper import CONVEX_SUITE
from repro.core.variance_model import empirical_variance_fn, measure_beta2, rho
from repro.data import convex_dataset
from repro.models.convex import solve_optimum as _w_star_impl


def _w_star(kind, X, y):
    return _w_star_impl(kind, X, y)


def run():
    rows = []
    total_us = 0.0
    for c in CONVEX_SUITE:
        n = min(c.num_samples, 2048)
        d = min(c.num_dims, 256)
        X, y, _ = convex_dataset(c.model, n, d, sparsity=c.sparsity,
                                 noise=c.noise, seed=0)
        X, y = jnp.asarray(X), jnp.asarray(y)
        ws = _w_star(c.model, X, y)
        vfn = empirical_variance_fn(c.model, X, y)
        dt, (b2, s2) = timeit(
            lambda: measure_beta2(vfn, ws, key=jax.random.PRNGKey(0),
                                  num_lines=6), reps=1)
        total_us += dt
        r = rho(b2, s2, jnp.zeros(d), ws)
        rows.append({"dataset": c.name, "model": c.model, "n": n, "d": d,
                     "sigma2": s2, "beta2": b2, "rho": r})
    save("bench_table1", {"rows": rows})
    order = sorted(rows, key=lambda r: -r["rho"])
    emit("table1_variance_measurements", total_us,
         "rho_order=" + ">".join(r["dataset"].split("-")[1] for r in order))


if __name__ == "__main__":
    run()
